"""Production training launcher.

Builds the mesh (production 16×16 / 2×16×16 when the host exposes enough
devices, else the largest (data, model) grid that fits), shards parameters
and optimizer state by the framework rules, and runs the training loop with
tape-scheduled data manifests, periodic checkpointing and straggler
monitoring.

On a CPU dev box::

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
      --reduced --steps 50 --batch 8 --seq 128

On a pod, the same command with ``--mesh pod`` (or ``multipod``) and real
shapes; ``--set k=v`` forwards any ModelConfig override (remat_policy,
microbatches, logits_bf16_ce, moe_gather_dispatch, attn_q_chunk, ...).
"""

from __future__ import annotations

import argparse
import dataclasses
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ARCHS, reduced
from ..distributed.checkpoint import load_checkpoint, save_checkpoint
from ..distributed.context import set_active_mesh
from ..distributed.fault_tolerance import StragglerMonitor, should_checkpoint
from ..distributed.sharding import batch_pspecs, param_pspecs, to_shardings
from ..training.optimizer import OptConfig
from ..training.train_step import init_train_state, make_train_step


def _auto_mesh(kind: str):
    devs = jax.devices()
    if kind == "pod":
        from .mesh import make_production_mesh

        return make_production_mesh(multi_pod=False)
    if kind == "multipod":
        from .mesh import make_production_mesh

        return make_production_mesh(multi_pod=True)
    # auto: largest (data, model) grid over available devices
    n = len(devs)
    model = 1
    while model * 2 <= min(8, n) and n % (model * 2) == 0:
        model *= 2
    data = n // model
    return jax.sharding.Mesh(np.asarray(devs).reshape(data, model), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", help="CPU-scale config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="auto", choices=["auto", "pod", "multipod"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--set", nargs="*", default=None, metavar="K=V")
    args = ap.parse_args()

    from .cli import parse_overrides

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg, periods=2)
        cfg = dataclasses.replace(cfg, vocab_size=min(cfg.vocab_size, 32768))
    overrides = parse_overrides(args.set or [])
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    mesh = _auto_mesh(args.mesh)
    set_active_mesh(mesh)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}  arch: {cfg.arch_id}")

    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"params: {n_params/1e6:.1f}M")

    psh = to_shardings(param_pspecs(params), mesh, params)
    params = jax.device_put(params, psh)
    opt_state = {
        "m": jax.device_put(opt_state["m"], psh),
        "v": jax.device_put(opt_state["v"], psh),
        "step": opt_state["step"],
    }

    step_fn = jax.jit(
        make_train_step(cfg, OptConfig(learning_rate=args.lr, warmup_steps=20,
                                       total_steps=args.steps))
    )

    start = 0
    ckpt = pathlib.Path(args.ckpt_dir)
    if args.resume and (ckpt / "manifest.json").exists():
        start, trees = load_checkpoint(ckpt, params=params, opt_state=opt_state)
        params, opt_state = trees["params"], trees["opt_state"]
        print(f"resumed from step {start}")

    rng = np.random.default_rng(0)
    monitor = StragglerMonitor()
    with mesh:
        for i in range(start, args.steps):
            tokens = jnp.asarray(
                np.minimum(rng.zipf(1.2, size=(args.batch, args.seq)), cfg.vocab_size - 1),
                jnp.int32,
            )
            batch = {"tokens": tokens}
            if cfg.enc_layers:
                batch["enc_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_enc_frames, cfg.d_model), cfg.cdtype
                )
            if cfg.num_vision_tokens:
                batch["vision_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_vision_tokens, cfg.d_model), cfg.cdtype
                )
            bsh = to_shardings(batch_pspecs(batch, mesh), mesh)
            batch = jax.device_put(batch, bsh)
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            monitor.record("self", i, time.time() - t0)
            if should_checkpoint(i + 1, args.ckpt_every, monitor.stragglers()):
                save_checkpoint(ckpt, i + 1, params=params, opt_state=opt_state)
            if (i + 1) % 10 == 0 or i + 1 == args.steps:
                print(f"step {i+1:>5d} loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.2f}")
    set_active_mesh(None)
    print("done")


if __name__ == "__main__":
    main()

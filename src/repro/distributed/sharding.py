"""Named-axis sharding rules (MaxText-style logical rules, path-based).

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod.  The pod axis is pure data parallelism (gradient all-reduce rides
the inter-pod links once per step); "model" carries tensor/expert parallelism;
decode KV caches are sequence-sharded over "model" (split-K decode), which
keeps every architecture's cache shardable regardless of its KV head count.

``param_pspecs``/``cache_pspecs`` walk a pytree and assign a PartitionSpec to
every leaf from suffix rules on the tree path.  Stacked-layer leaves (scan)
carry one extra leading axis; the rule table is written for the unstacked
layer and a leading ``None`` is prepended automatically when the leaf has one
more dimension than its rule.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL = "model"


def dp_axes(mesh: Mesh):
    """Batch ("data-parallel") mesh axes, including the pod axis if present."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# --- parameter rules: (path suffix) -> base spec (unstacked layer) ----------
_PARAM_RULES: list[tuple[tuple[str, ...], P]] = [
    # embeddings / head
    (("embed",), P(MODEL, None)),
    (("lm_head",), P(None, MODEL)),
    # attention / mlstm projections
    (("mixer", "wq"), P(None, MODEL)),
    (("mixer", "wk"), P(None, MODEL)),
    (("mixer", "wv"), P(None, MODEL)),
    (("mixer", "wo"), P(MODEL, None)),
    (("mixer", "bq"), P(MODEL)),
    (("mixer", "bk"), P(MODEL)),
    (("mixer", "bv"), P(MODEL)),
    (("xmixer", "wq"), P(None, MODEL)),
    (("xmixer", "wk"), P(None, MODEL)),
    (("xmixer", "wv"), P(None, MODEL)),
    (("xmixer", "wo"), P(MODEL, None)),
    # MLA
    (("mixer", "wq_a"), P(None, None)),
    (("mixer", "wq_b"), P(None, MODEL)),
    (("mixer", "wkv_a"), P(None, None)),
    (("mixer", "wk_b"), P(None, MODEL)),
    (("mixer", "wv_b"), P(None, MODEL)),
    # dense FFN
    (("ffn", "w_gate"), P(None, MODEL)),
    (("ffn", "w_in"), P(None, MODEL)),
    (("ffn", "w_out"), P(MODEL, None)),
    # MoE (expert-parallel over "model")
    (("moe", "router"), P(None, None)),
    (("moe", "w_gate"), P(MODEL, None, None)),
    (("moe", "w_in"), P(MODEL, None, None)),
    (("moe", "w_out"), P(MODEL, None, None)),
    (("shared", "w_gate"), P(None, MODEL)),
    (("shared", "w_in"), P(None, MODEL)),
    (("shared", "w_out"), P(MODEL, None)),
    # Mamba
    (("mixer", "in_proj"), P(None, MODEL)),
    (("mixer", "conv_w"), P(None, MODEL)),
    (("mixer", "conv_b"), P(MODEL)),
    (("mixer", "x_proj"), P(MODEL, None)),
    (("mixer", "dt_proj"), P(None, MODEL)),
    (("mixer", "dt_bias"), P(MODEL)),
    (("mixer", "A_log"), P(MODEL, None)),
    (("mixer", "D_skip"), P(MODEL)),
    (("mixer", "out_proj"), P(MODEL, None)),
    # xLSTM
    (("mixer", "w_if"), P(None, None)),
    (("mixer", "b_if"), P(None)),
    (("mixer", "ln_out"), P(MODEL)),
    (("mixer", "w_in"), P(None, MODEL)),  # slstm input proj
    (("mixer", "b_in"), P(MODEL)),
    (("mixer", "r"), P(None, None, MODEL, None)),
]

_CACHE_RULES: list[tuple[tuple[str, ...], Any]] = []  # built per-mesh below


def _path_names(path) -> tuple[str, ...]:
    names = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            names.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            names.append(f"[{e.idx}]")
        else:
            names.append(str(e))
    return tuple(names)


def _match(names: tuple[str, ...], suffix: tuple[str, ...]) -> bool:
    if len(suffix) > len(names):
        return False
    return names[-len(suffix):] == suffix


def _fit(spec: P, ndim: int) -> P:
    """Prepend Nones for stacked-layer leading axes; sanity-check rank."""
    if len(spec) == ndim:
        return spec
    if len(spec) < ndim:
        return P(*([None] * (ndim - len(spec)) + list(spec)))
    raise ValueError(f"spec {spec} has more dims than leaf rank {ndim}")


def safe_pspec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop sharding on any axis the mesh axes do not divide (replicate it).

    Keeps every (arch x shape) cell shardable: e.g. ``long_500k`` has global
    batch 1 (sequence/state dims carry the parallelism instead), and vision /
    encoder memory lengths (1601, 1500) do not divide the model axis.
    """
    out = []
    for ax, s in enumerate(spec):
        if s is None:
            out.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(s if shape[ax] % size == 0 else None)
    return P(*out)


def param_pspecs(params) -> Any:
    """PartitionSpec tree for a parameter pytree (norms replicate)."""

    def assign(path, leaf):
        names = _path_names(path)
        for suffix, spec in _PARAM_RULES:
            if _match(names, suffix):
                return _fit(spec, leaf.ndim)
        # norms, small biases, routers not matched above: replicate
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(assign, params)


def cache_pspecs(cache, mesh: Mesh) -> Any:
    """PartitionSpec tree for a decode cache.

    Self-attention KV and MLA compressed caches are sequence-sharded over
    "model" (split-K decode); recurrent states shard their channel dim; cross
    caches (vision/encoder memory) replicate over "model" (small).
    """
    dp = dp_axes(mesh)
    rules = [
        (("cross", "k"), P(dp, None, None, None)),
        (("cross", "v"), P(dp, None, None, None)),
        (("mix", "k"), P(dp, MODEL, None, None)),
        (("mix", "v"), P(dp, MODEL, None, None)),
        (("mix", "ckv"), P(dp, MODEL, None)),
        (("mix", "kr"), P(dp, MODEL, None)),
        (("mix", "conv"), P(dp, None, MODEL)),
        (("mix", "ssm"), P(dp, MODEL, None)),
        (("mix", "C"), P(dp, None, MODEL, None)),
        (("mix", "n"), P(dp, None, MODEL)),
        (("mix", "m"), P(dp, None)),
        (("mix", "c"), P(dp, None, MODEL)),
        (("mix", "h"), P(dp, None, MODEL)),
    ]
    # VLM: the scanned period mixes self-attn ("mix".k of rank 4, seq-shardable)
    # with cross-attn xattn layers whose "mix".k holds vision tokens; those are
    # distinguished by path (l4 vs l0-l3) only through length — here we rely on
    # mem-length caches being under layers whose pattern kind is xattn, which
    # share the ("mix","k") suffix.  Sequence-sharding a 1601-token vision
    # cache over model=16 would not divide, so dryrun pads cross caches or the
    # rule below replicates them; we special-case by rank==4 and tiny seq via
    # the fallback in `assign`.

    def assign(path, leaf):
        names = _path_names(path)
        for suffix, spec in rules:
            if _match(names, suffix):
                return safe_pspec(_fit(spec, leaf.ndim), leaf.shape, mesh)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(assign, cache)


def batch_pspecs(batch, mesh: Mesh) -> Any:
    """Inputs: shard the leading (batch) axis over all data axes."""
    dp = dp_axes(mesh)

    def assign(path, leaf):
        if leaf.ndim == 0:
            return P()
        spec = P(*([dp] + [None] * (leaf.ndim - 1)))
        return safe_pspec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(assign, batch)


def to_shardings(pspecs, mesh: Mesh, tree=None):
    """PartitionSpecs -> NamedShardings; with ``tree`` (abstract leaves of the
    same structure) non-dividing axes are demoted to replication first (e.g.
    a 256206-row vocab on a 16-way model axis)."""
    if tree is None:
        return jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            pspecs,
            is_leaf=lambda x: isinstance(x, P),
        )
    return jax.tree.map(
        lambda s, leaf: NamedSharding(mesh, safe_pspec(s, leaf.shape, mesh)),
        pspecs,
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )

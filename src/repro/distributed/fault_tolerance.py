"""Fault tolerance at pod scale: straggler detection + elastic re-meshing.

These are the pure control-plane pieces: detecting slow/dead workers from
step-duration telemetry, deriving a survivor mesh, and scaling the batch.
They are exercised by tests and by the training example's simulated
preemption; on a real cluster the same plans drive
``jax.distributed``/coordinator restarts.
"""

from __future__ import annotations

import dataclasses
import statistics
from typing import Iterable

__all__ = ["StragglerMonitor", "RemeshPlan", "remesh_plan", "should_checkpoint"]


class StragglerMonitor:
    """Per-worker step-duration telemetry with EMA and robust flagging."""

    def __init__(self, alpha: float = 0.2):
        self.alpha = alpha
        self.ema: dict[str, float] = {}
        self.last_seen: dict[str, int] = {}

    def record(self, worker: str, step: int, duration_s: float) -> None:
        prev = self.ema.get(worker)
        self.ema[worker] = (
            duration_s if prev is None else (1 - self.alpha) * prev + self.alpha * duration_s
        )
        self.last_seen[worker] = step

    def stragglers(self, threshold: float = 2.0) -> list[str]:
        """Workers whose EMA step time exceeds ``threshold x`` the median."""
        if len(self.ema) < 2:
            return []
        med = statistics.median(self.ema.values())
        return sorted(w for w, v in self.ema.items() if v > threshold * med)

    def dead(self, current_step: int, max_lag: int = 3) -> list[str]:
        """Workers that have not reported for ``max_lag`` steps."""
        return sorted(
            w for w, s in self.last_seen.items() if current_step - s > max_lag
        )


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    """Elastic-scaling decision after losing devices."""

    shape: tuple[int, ...]  # new mesh shape
    axis_names: tuple[str, ...]
    devices_used: int
    devices_dropped: int
    batch_scale: float  # new global batch as a fraction of the old
    reshard_model_axis: bool  # params must move (expensive) vs pure DP shrink


def remesh_plan(
    alive_devices: int,
    old_shape: tuple[int, ...],
    axis_names: tuple[str, ...] = ("data", "model"),
) -> RemeshPlan:
    """Largest survivor mesh that preserves the model axis if possible.

    Preference order: (1) keep the model axis intact and shrink the data
    (and pod) axes — parameters stay put, only the batch shrinks; (2) if even
    one model-axis replica no longer fits, shrink the model axis to the
    largest power-of-two divisor that fits (requires parameter resharding).
    """
    *rest, model = old_shape
    data_total = 1
    for r in rest:
        data_total *= r
    if alive_devices >= model:
        new_data = alive_devices // model
        # fold pods back in if the pod axis survives whole multiples
        if len(rest) == 2:  # (pod, data)
            pod, data = rest
            new_pod = max(1, min(pod, new_data // data)) if data <= new_data else 1
            new_data_axis = new_data // new_pod
            shape = (new_pod, new_data_axis, model)
        else:
            shape = (new_data, model)
        used = new_data * model
        return RemeshPlan(
            shape=shape,
            axis_names=axis_names,
            devices_used=used,
            devices_dropped=alive_devices - used,
            batch_scale=new_data / data_total,
            reshard_model_axis=False,
        )
    # degraded mode: shrink model axis
    new_model = 1
    while new_model * 2 <= alive_devices and model % (new_model * 2) == 0:
        new_model *= 2
    new_data = alive_devices // new_model
    shape = (new_data, new_model) if len(rest) == 1 else (1, new_data, new_model)
    return RemeshPlan(
        shape=shape,
        axis_names=axis_names,
        devices_used=new_data * new_model,
        devices_dropped=alive_devices - new_data * new_model,
        batch_scale=new_data / data_total,
        reshard_model_axis=True,
    )


def should_checkpoint(step: int, every: int, alarms: Iterable[str]) -> bool:
    """Periodic checkpointing, forced early when stragglers/dead detected."""
    return step % every == 0 or bool(list(alarms))

"""Active-mesh context: lets model code emit sharding hints without taking a
mesh argument through every layer.

``set_active_mesh(mesh)`` is called by the launcher (dry-run / trainer) before
tracing; ``shard_hint(x, spec_fn)`` is a no-op when no mesh is active (CPU
tests, single device), so model code is unchanged off-cluster.
"""

from __future__ import annotations

from typing import Callable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Mesh | None = None


def set_active_mesh(mesh: Mesh | None) -> None:
    global _MESH
    _MESH = mesh


def active_mesh() -> Mesh | None:
    return _MESH


def shard_hint(x: jax.Array, spec_fn: Callable[[Mesh], P]) -> jax.Array:
    """Apply ``with_sharding_constraint`` if a mesh is active (divisibility-
    guarded); identity otherwise."""
    if _MESH is None:
        return x
    from .sharding import safe_pspec

    spec = safe_pspec(spec_fn(_MESH), x.shape, _MESH)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, spec))


def dp_spec(mesh: Mesh):
    from .sharding import dp_axes

    return dp_axes(mesh)

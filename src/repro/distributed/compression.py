"""Gradient compression for cross-pod sync: top-k + error feedback, int8.

At 2+ pods the gradient all-reduce over the inter-pod links is the scarce
resource (50 GB/s/link vs 819 GB/s HBM).  Two standard compressors are
provided as pure functions; ``compressed_grads`` wraps either around a
gradient pytree with persistent error-feedback state so the training loop can
compress before the pod-axis reduction and decompress after.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "topk_compress",
    "topk_decompress",
    "int8_compress",
    "int8_decompress",
    "init_error_feedback",
    "compressed_grads",
]


def topk_compress(g: jax.Array, ratio: float):
    """Keep the largest-|g| ``ratio`` fraction -> (values, flat indices)."""
    flat = g.reshape(-1)
    k = max(1, int(flat.size * ratio))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(values, idx, shape, dtype=jnp.float32):
    flat = jnp.zeros((int(jnp.prod(jnp.array(shape))),), dtype)
    return flat.at[idx].set(values.astype(dtype)).reshape(shape)


def int8_compress(g: jax.Array):
    """Symmetric per-tensor int8 quantisation -> (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale, dtype=jnp.float32):
    return q.astype(dtype) * scale


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grads(grads, ef_state, method: str = "topk", ratio: float = 0.01):
    """Compress+decompress a gradient pytree with error feedback.

    Returns ``(effective_grads, new_ef_state, bytes_ratio)`` where
    ``effective_grads`` is what the optimizer sees (decompressed), and the
    residual (what compression dropped) is carried to the next step.
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        if method == "topk":
            vals, idx = topk_compress(target, ratio)
            rec = topk_decompress(vals, idx, target.shape)
        elif method == "int8":
            q, s = int8_compress(target)
            rec = int8_decompress(q, s)
        else:
            raise ValueError(method)
        return rec, target - rec

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    recs, resids = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    bytes_ratio = {"topk": ratio * 2.0, "int8": 0.25}[method]  # vs f32
    return (
        jax.tree.unflatten(treedef, recs),
        jax.tree.unflatten(treedef, resids),
        bytes_ratio,
    )

"""Checkpointing with LTSP-scheduled archive restore.

Two tiers:

* **hot tier** — plain directory of ``.npy`` leaves + manifest (save/restore
  for crash recovery, bit-exact, no external deps);
* **archive tier** — checkpoint shards written sequentially to the simulated
  tape library.  A multi-pod restore requests every shard once per consumer
  pod (that multiplicity is exactly LTSP's request multiplicity); the restore
  read order is produced by the paper's DP/SimpleDP schedulers, minimising the
  *mean* shard arrival time so pods start resharding work as early as
  possible instead of waiting for a positional sweep.
"""

from __future__ import annotations

import json
import pathlib
import re
from typing import Any

import jax
import numpy as np

from ..storage.tape import ReadPlan, TapeLibrary

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "archive_to_tape",
    "plan_restore",
]

_SEP = "::"


def _flatten_with_names(tree) -> list[tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = _SEP.join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        out.append((name, np.asarray(leaf)))
    return out


def save_checkpoint(path: str | pathlib.Path, step: int, **trees: Any) -> None:
    """Write named pytrees (e.g. ``params=..., opt_state=...``) + manifest."""
    path = pathlib.Path(path)
    path.mkdir(parents=True, exist_ok=True)
    manifest: dict[str, Any] = {"step": int(step), "trees": {}}
    for tree_name, tree in trees.items():
        treedef = jax.tree_util.tree_structure(tree)
        leaves = _flatten_with_names(tree)
        manifest["trees"][tree_name] = {
            "treedef": str(treedef),
            "leaves": [
                {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
                for n, a in leaves
            ],
        }
        for i, (name, arr) in enumerate(leaves):
            safe = re.sub(r"[^A-Za-z0-9_.-]", "_", f"{tree_name}{_SEP}{name}")
            np.save(path / f"{i:05d}_{safe}.npy", arr)
        manifest["trees"][tree_name]["files"] = [
            f"{i:05d}_" + re.sub(r"[^A-Za-z0-9_.-]", "_", f"{tree_name}{_SEP}{n}") + ".npy"
            for i, (n, _) in enumerate(leaves)
        ]
    (path / "manifest.json").write_text(json.dumps(manifest))


def load_checkpoint(path: str | pathlib.Path, **templates: Any):
    """Restore pytrees by structure templates -> (step, {name: tree})."""
    path = pathlib.Path(path)
    manifest = json.loads((path / "manifest.json").read_text())
    out = {}
    for tree_name, template in templates.items():
        info = manifest["trees"][tree_name]
        arrays = [np.load(path / f) for f in info["files"]]
        treedef = jax.tree_util.tree_structure(template)
        out[tree_name] = jax.tree_util.tree_unflatten(treedef, arrays)
    return manifest["step"], out


# ---------------------------------------------------------------------------
# archive tier (tape-backed) — the paper's technique as a framework feature
# ---------------------------------------------------------------------------
def archive_to_tape(
    library: TapeLibrary, ckpt_name: str, params, bytes_per_elem: int = 4
) -> list[str]:
    """Append every leaf of a checkpoint sequentially to the tape library."""
    names = []
    for leaf_name, arr in _flatten_with_names(params):
        fname = f"{ckpt_name}/{leaf_name}"
        library.store(fname, max(1, arr.size * bytes_per_elem))
        names.append(fname)
    return names


def plan_restore(
    library: TapeLibrary,
    shard_names: list[str],
    consumers_per_shard: int | dict[str, int] = 1,
    policy: str = "simpledp",
    backend: str | None = None,
    cache=None,
    *,
    context=None,
) -> list[ReadPlan]:
    """LTSP-scheduled restore: order shard reads to minimise mean arrival.

    ``consumers_per_shard`` is the request multiplicity (e.g. the number of
    pods that need the shard before they can start their reshard step).
    ``policy`` selects any registered solver; ``context`` (an
    :class:`repro.core.ExecutionContext`, defaulting to the library's own)
    selects backend/cache/numeric options — with the library context carrying
    a :class:`repro.core.SolveCache`, a restore re-planned against an
    unchanged archive is pure cache hits.  Device backends plan every
    cartridge in a few size-bucketed launches.  ``backend=``/``cache=`` are
    the deprecated pre-context spellings (warn, then fold into a context).
    """
    from ..core.context import resolve_context

    ctx = resolve_context(
        context, backend=backend, cache=cache, default=library.context
    )
    if isinstance(consumers_per_shard, int):
        requests = {n: consumers_per_shard for n in shard_names}
    else:
        requests = dict(consumers_per_shard)
    return library.schedule(requests, policy=policy, context=ctx)
